"""ShapeDtypeStruct stand-ins for every dry-run cell (no allocation).

``input_specs(cfg, shape)`` builds the abstract batch for a cell;
``abstract_state`` / ``abstract_cache`` eval_shape the train state and KV
cache.  Everything here is weak-type-correct and shardable.
"""

from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.configs.shapes import ShapeSpec
from repro.models import transformer as T
from repro.optim.adamw import OptimizerConfig
from repro.train.state import make_train_state

__all__ = ["input_specs", "abstract_state", "abstract_cache",
           "abstract_params"]


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def input_specs(cfg: T.ModelConfig, shape: ShapeSpec) -> dict:
    """Abstract batch for (cfg, shape).

    train:   {tokens|embeds, labels[, positions]}
    prefill: {tokens|embeds[, positions]}
    decode:  {tokens (B,), index ()}  — the cache comes from
             ``abstract_cache`` (it is carried state, not an input).
    """
    B, S = shape.global_batch, shape.seq_len
    if shape.kind == "decode":
        return {"tokens": _sds((B,), jnp.int32),
                "index": _sds((), jnp.int32)}
    out: dict = {}
    if cfg.input_kind == "tokens":
        out["tokens"] = _sds((B, S), jnp.int32)
    else:
        out["embeds"] = _sds((B, S, cfg.d_model), jnp.bfloat16)
        if cfg.rope_kind == "mrope":
            out["positions"] = _sds((3, B, S), jnp.int32)
    if shape.kind == "train":
        out["labels"] = _sds((B, S), jnp.int32)
    return out


def abstract_params(cfg: T.ModelConfig):
    return jax.eval_shape(lambda: T.init_model(jax.random.PRNGKey(0), cfg))


def abstract_state(cfg: T.ModelConfig):
    return jax.eval_shape(
        lambda: make_train_state(T.init_model(jax.random.PRNGKey(0), cfg)))


def abstract_cache(cfg: T.ModelConfig, batch: int, max_len: int,
                   dtype=jnp.bfloat16):
    return jax.eval_shape(
        lambda: T.init_cache(batch, max_len, cfg, dtype))
