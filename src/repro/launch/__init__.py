"""Launchers: production mesh, multi-pod dry-run, train/serve drivers.

NOTE: do not import repro.launch.dryrun from library code — it sets
XLA_FLAGS for 512 host devices at import time (dry-run only).
"""

from repro.launch.mesh import make_production_mesh, make_host_mesh  # noqa: F401
