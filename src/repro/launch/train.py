"""End-to-end training driver.

CPU-scale by default (smoke configs); on a real cluster the same driver
runs under ``jax.distributed.initialize()`` with the production mesh
(see launch/README_MULTIHOST.md).  Features exercised here: deterministic
resumable data, NaN-guarded steps, atomic keep-N checkpoints,
resume-latest, fault-policy rollback.

  PYTHONPATH=src python -m repro.launch.train --arch qwen3-1.7b \
      --smoke --steps 50 --batch 8 --seq 64 --ckpt-dir /tmp/run1
"""

from __future__ import annotations

import argparse
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCH_IDS, get_config, get_smoke, with_overrides
from repro.data.char_corpus import build_corpus
from repro.data.loader import DeterministicLoader
from repro.models import causal_lm as LM
from repro.models import transformer as T
from repro.optim.adamw import OptimizerConfig
from repro.train import (FaultPolicy, latest_step, make_train_state,
                         make_train_step, restore_checkpoint,
                         save_checkpoint)


def make_batch_fn(cfg: T.ModelConfig, seq_len: int, corpus: np.ndarray):
    n = len(corpus) - seq_len - 1

    def batch_fn(key, global_batch):
        starts = jax.random.randint(key, (global_batch,), 0, n)
        idx = starts[:, None] + jnp.arange(seq_len + 1)[None, :]
        chunk = jnp.asarray(corpus)[idx]
        toks = chunk[:, :-1].astype(jnp.int32) % cfg.vocab_size
        labels = chunk[:, 1:].astype(jnp.int32) % cfg.vocab_size
        batch = {"labels": labels}
        if cfg.input_kind == "tokens":
            batch["tokens"] = toks
        else:
            # modality-frontend stub: hash tokens into embeddings
            table = jax.random.normal(jax.random.PRNGKey(1),
                                      (cfg.vocab_size, cfg.d_model))
            batch["embeds"] = table[toks]
            if cfg.rope_kind == "mrope":
                pos = jnp.broadcast_to(jnp.arange(seq_len),
                                       toks.shape)
                batch["positions"] = jnp.broadcast_to(
                    pos, (3,) + toks.shape)
        return batch

    return batch_fn


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS, default="qwen3-1.7b")
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config (CPU-runnable)")
    ap.add_argument("--linear-impl", default=None)
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--accum", type=int, default=1)
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--ckpt-every", type=int, default=20)
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_smoke(args.arch) if args.smoke else get_config(args.arch)
    if args.linear_impl:
        cfg = with_overrides(cfg, linear_impl=args.linear_impl)
    print(f"arch={cfg.name} impl={cfg.linear_impl} "
          f"steps={args.steps} B={args.batch} T={args.seq}")

    corpus = build_corpus(200_000, seed=args.seed)
    loader = DeterministicLoader(make_batch_fn(cfg, args.seq, corpus),
                                 args.batch, seed=args.seed)

    params = T.init_model(jax.random.PRNGKey(args.seed), cfg)
    state = make_train_state(params)
    n_params = sum(x.size for x in jax.tree.leaves(params))
    print(f"params: {n_params:,}")

    opt_cfg = OptimizerConfig(lr=args.lr, total_steps=args.steps,
                              warmup_steps=max(args.steps // 20, 1))
    step_fn = jax.jit(make_train_step(
        lambda p, b: LM.lm_loss(p, b, cfg), opt_cfg,
        accum_steps=args.accum))

    start = 0
    if args.ckpt_dir and latest_step(args.ckpt_dir) is not None:
        state, extra = restore_checkpoint(args.ckpt_dir, state)
        start = int(extra.get("cursor", {}).get("step", 0))
        loader.resume(extra["cursor"])
        print(f"resumed from step {start}")

    policy = FaultPolicy()
    t0 = time.time()
    for s in range(start, args.steps):
        batch = loader.batch_at(s)
        state, metrics = step_fn(state, batch)
        if policy.on_metrics(jax.device_get(metrics)):
            print("!! rollback: too many consecutive skipped steps")
            state, extra = restore_checkpoint(args.ckpt_dir, state)
            policy.reset()
        if (s + 1) % args.log_every == 0:
            m = jax.device_get(metrics)
            dt = (time.time() - t0) / (s + 1 - start)
            print(f"step {s+1:5d} loss={float(m['loss']):.4f} "
                  f"gnorm={float(m['grad_norm']):.3f} "
                  f"lr={float(m['lr']):.2e} {dt*1e3:.0f} ms/step")
        if args.ckpt_dir and (s + 1) % args.ckpt_every == 0:
            save_checkpoint(args.ckpt_dir, s + 1, state,
                            extra={"cursor": {"seed": args.seed,
                                              "step": s + 1}})
    print(f"done in {time.time()-t0:.1f}s")


if __name__ == "__main__":
    main()
