"""End-to-end training driver with recovery orchestration.

CPU-scale by default (smoke configs); on a real cluster the same driver
runs under ``jax.distributed.initialize()`` with the production mesh
(see launch/README_MULTIHOST.md).  Features exercised here: deterministic
resumable data, NaN-guarded steps, atomic keep-N checkpoints with
verified-integrity restore (corrupt checkpoints are quarantined and the
restore walks back to the newest valid step), fault-policy rollback that
coherently rewinds the loop counter / data cursor / LR schedule, and
``run_with_recovery`` restarts with exponential backoff around the whole
loop.  ``--chaos-spec`` arms deterministic fault injection
(train/chaos.py) so every one of those paths can be exercised on demand:

  PYTHONPATH=src python -m repro.launch.train --arch qwen3-1.7b \
      --smoke --steps 50 --batch 8 --seq 64 --ckpt-dir /tmp/run1 \
      --chaos-spec 'nan@13+5;corrupt@18:bitflip;preempt@19'

Tests drive the same code through ``train(args)`` (no subprocess
needed); it returns the final state for parity assertions.
"""

from __future__ import annotations

import argparse
import os
import time
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import (ARCH_IDS, get_config, get_smoke, with_overrides,
                           with_quantized_io)
from repro.data.char_corpus import build_corpus
from repro.data.loader import DeterministicLoader
from repro.models import causal_lm as LM
from repro.models import transformer as T
from repro.optim.adamw import OptimizerConfig
from repro.train import (FaultEventLog, FaultPolicy, RESUME_LATEST,
                         StragglerDetector, latest_valid_step,
                         make_pod_train_step, make_train_state,
                         make_train_step, restore_checkpoint,
                         run_with_recovery, save_checkpoint)
from repro.train.chaos import ChaosSchedule


def make_batch_fn(cfg: T.ModelConfig, seq_len: int, corpus: np.ndarray):
    n = len(corpus) - seq_len - 1

    def batch_fn(key, global_batch):
        starts = jax.random.randint(key, (global_batch,), 0, n)
        idx = starts[:, None] + jnp.arange(seq_len + 1)[None, :]
        chunk = jnp.asarray(corpus)[idx]
        toks = chunk[:, :-1].astype(jnp.int32) % cfg.vocab_size
        labels = chunk[:, 1:].astype(jnp.int32) % cfg.vocab_size
        batch = {"labels": labels}
        if cfg.input_kind == "tokens":
            batch["tokens"] = toks
        else:
            # modality-frontend stub: hash tokens into embeddings
            table = jax.random.normal(jax.random.PRNGKey(1),
                                      (cfg.vocab_size, cfg.d_model))
            batch["embeds"] = table[toks]
            if cfg.rope_kind == "mrope":
                pos = jnp.broadcast_to(jnp.arange(seq_len),
                                       toks.shape)
                batch["positions"] = jnp.broadcast_to(
                    pos, (3,) + toks.shape)
        return batch

    return batch_fn


def build_parser() -> argparse.ArgumentParser:
    """CLI for the driver (shared with tests, which build an args
    namespace via ``build_parser().parse_args([...])``)."""
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS, default="qwen3-1.7b")
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config (CPU-runnable)")
    ap.add_argument("--linear-impl", default=None)
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--accum", type=int, default=1)
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--ckpt-every", type=int, default=20)
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--quantize", action="store_true",
                    help="int8 SPM quantization: activation I/O on the "
                         "fused kernel path + per-stage-scaled coefficient "
                         "tables (configs.with_quantized_io; see "
                         "docs/quantization.md)")
    ap.add_argument("--pod-dp", type=int, default=0,
                    help="data-parallel pod size: >1 runs the train step "
                         "inside a shard_map over a ('pod',) mesh of that "
                         "many devices (batch must divide by it)")
    ap.add_argument("--compress-pod-grads", action="store_true",
                    help="with --pod-dp: reduce gradients through the int8 "
                         "error-feedback compressed psum instead of a "
                         "plain pmean (optim/compression.py)")
    ap.add_argument("--chaos-spec", default="",
                    help="deterministic fault-injection plan, e.g. "
                         "'nan@13+5;corrupt@18:bitflip;preempt@19' "
                         "(see train/chaos.py)")
    ap.add_argument("--chaos-seed", type=int, default=0)
    ap.add_argument("--event-log", default="",
                    help="fault-event JSONL path (default: "
                         "<ckpt-dir>/events.jsonl when --ckpt-dir is set)")
    ap.add_argument("--max-restarts", type=int, default=3,
                    help="restart budget for run_with_recovery")
    ap.add_argument("--backoff-base", type=float, default=0.5)
    return ap


def train(args: argparse.Namespace,
          event_log: Optional[FaultEventLog] = None,
          chaos: Optional[ChaosSchedule] = None) -> dict:
    """Run the full training job described by ``args`` and return the
    final train state.  Builds the recovery orchestration: the inner
    ``loop(resume)`` holds all step/rollback logic, ``run_with_recovery``
    restarts it on failure with exponential backoff and a restart budget.

    ``event_log`` / ``chaos`` override the ones built from ``args``
    (tests pass a shared ChaosSchedule so fire-once state survives a
    simulated process death across two ``train`` calls)."""
    cfg = get_smoke(args.arch) if args.smoke else get_config(args.arch)
    if args.linear_impl:
        cfg = with_overrides(cfg, linear_impl=args.linear_impl)
    if getattr(args, "quantize", False):
        cfg = with_quantized_io(cfg)
    n_pod = max(getattr(args, "pod_dp", 0), 0)
    if getattr(args, "compress_pod_grads", False):
        cfg = with_overrides(cfg, compress_pod_grads=True)
    if n_pod > 1 and args.batch % n_pod:
        raise ValueError(f"--batch {args.batch} must divide by "
                         f"--pod-dp {n_pod}")
    print(f"arch={cfg.name} impl={cfg.linear_impl} "
          f"steps={args.steps} B={args.batch} T={args.seq}"
          + (f" pod={n_pod}"
             f"{' (compressed grads)' if cfg.compress_pod_grads else ''}"
             if n_pod > 1 else ""))

    if event_log is None:
        path = args.event_log or (os.path.join(args.ckpt_dir,
                                               "events.jsonl")
                                  if args.ckpt_dir else None)
        event_log = FaultEventLog(path)
    if chaos is None and args.chaos_spec:
        chaos = ChaosSchedule.parse(args.chaos_spec, seed=args.chaos_seed)

    corpus = build_corpus(200_000, seed=args.seed)

    def fresh_loader() -> DeterministicLoader:
        return DeterministicLoader(make_batch_fn(cfg, args.seq, corpus),
                                   args.batch, seed=args.seed)

    opt_cfg = OptimizerConfig(lr=args.lr, total_steps=args.steps,
                              warmup_steps=max(args.steps // 20, 1))
    # chaos_guard is always on: with poison=0 the step is bit-identical
    # to a guard-free build, and the single compiled step serves healthy
    # and poisoned iterations alike.
    loss_fn = lambda p, b: LM.lm_loss(p, b, cfg)
    if n_pod > 1:
        from jax.sharding import Mesh
        devs = jax.devices()
        if len(devs) < n_pod:
            raise ValueError(f"--pod-dp {n_pod} needs {n_pod} devices, "
                             f"have {len(devs)}")
        mesh = Mesh(np.asarray(devs[:n_pod]).reshape(n_pod), ("pod",))
        step_fn = jax.jit(make_pod_train_step(
            loss_fn, opt_cfg, mesh, compress=cfg.compress_pod_grads,
            accum_steps=args.accum, chaos_guard=True))
    else:
        step_fn = jax.jit(make_train_step(
            loss_fn, opt_cfg, accum_steps=args.accum, chaos_guard=True))

    def init_state() -> dict:
        params = T.init_model(jax.random.PRNGKey(args.seed), cfg)
        state = make_train_state(
            params,
            ef_pod=n_pod if (n_pod > 1 and cfg.compress_pod_grads) else 0)
        n_params = sum(x.size for x in jax.tree.leaves(params))
        print(f"params: {n_params:,}")
        return state

    def try_restore(state: dict, loader: DeterministicLoader,
                    required: bool):
        """Restore the newest VALID checkpoint, or fall back to a fresh
        start.  Returns (state, start_step, loader).  ``required`` marks
        an explicit resume intent (rollback / restart): finding nothing
        then is an event worth logging, not just a cold start."""
        step = (latest_valid_step(args.ckpt_dir, event_log=event_log)
                if args.ckpt_dir else None)
        if step is None:
            if required:
                print("!! no valid checkpoint to resume from; "
                      "restarting from scratch")
                event_log.emit("resume_fallback_fresh")
            return state, 0, loader
        state, extra = restore_checkpoint(
            args.ckpt_dir, state, step=step, event_log=event_log)
        # LR schedule rewinds automatically: it is driven by opt.count
        # inside the restored state.  The loop counter and data cursor
        # rewind here.
        if not loader.resume(extra.get("cursor")):
            event_log.emit("cursor_missing", step=step)
        start = int(extra.get("cursor", {}).get("step", step))
        print(f"resumed from step {start}")
        return state, start, loader

    def loop(resume: Optional[int]) -> dict:
        """One attempt at the training loop.  ``resume=None`` cold-starts
        (auto-resuming if checkpoints exist); ``RESUME_LATEST`` is
        run_with_recovery's explicit restore instruction after a crash."""
        loader = fresh_loader()
        state, start, loader = try_restore(
            init_state(), loader, required=resume == RESUME_LATEST)

        policy = FaultPolicy()
        straggler = StragglerDetector(event_log=event_log)
        t0 = time.time()
        s = start
        while s < args.steps:
            if chaos is not None:
                chaos.pre_step(s)
            batch = loader.batch_at(s)
            poison = chaos.poison(s) if chaos is not None else 0.0
            t_step = time.time()
            state, metrics = step_fn(state, batch, poison)
            metrics = jax.device_get(metrics)
            straggler.observe(s, time.time() - t_step)
            if metrics.get("skipped"):
                event_log.emit("skip", step=s, cause="non-finite grads")
            if policy.on_metrics(metrics):
                # Coherent rollback: state, loop counter, and data
                # cursor all rewind to the restored step (or to a fresh
                # start when no checkpoint survives).
                print("!! rollback: too many consecutive skipped steps")
                event_log.emit("rollback", step=s,
                               cause=f"{policy.consecutive_skips} "
                                     "consecutive skips")
                state, s, loader = try_restore(
                    init_state(), fresh_loader(), required=True)
                policy.reset()
                continue
            s += 1
            if s % args.log_every == 0:
                dt = (time.time() - t0) / max(s - start, 1)
                print(f"step {s:5d} loss={float(metrics['loss']):.4f} "
                      f"gnorm={float(metrics['grad_norm']):.3f} "
                      f"lr={float(metrics['lr']):.2e} "
                      f"{dt*1e3:.0f} ms/step")
            if args.ckpt_dir and s % args.ckpt_every == 0:
                save_checkpoint(args.ckpt_dir, s, state,
                                extra={"cursor": {"seed": args.seed,
                                                  "step": s}})
            if chaos is not None:
                chaos.post_step(s - 1, args.ckpt_dir or None,
                                event_log=event_log)
        print(f"done in {time.time()-t0:.1f}s "
              f"(skips={policy.total_skips})")
        return state

    return run_with_recovery(loop, max_restarts=args.max_restarts,
                             backoff_base=args.backoff_base,
                             event_log=event_log)


def main() -> None:
    train(build_parser().parse_args())


if __name__ == "__main__":
    main()
