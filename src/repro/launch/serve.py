"""Batched serving driver (smoke-scale on CPU; production mesh on TPU).

  PYTHONPATH=src python -m repro.launch.serve --arch qwen3-1.7b --smoke \
      --batch 4 --prompt-len 16 --new-tokens 16
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import ARCH_IDS, get_config, get_smoke, with_overrides
from repro.models import transformer as T
from repro.serve import ServeEngine


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS, default="qwen3-1.7b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--linear-impl", default=None)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--new-tokens", type=int, default=16)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--cache-dtype", choices=("bfloat16", "float32"),
                    default="bfloat16",
                    help="KV-cache dtype (default matches the engine's "
                         "bf16 default; float32 for parity debugging)")
    args = ap.parse_args()

    cfg = get_smoke(args.arch) if args.smoke else get_config(args.arch)
    if args.linear_impl:
        cfg = with_overrides(cfg, linear_impl=args.linear_impl)
    if cfg.input_kind != "tokens":
        print(f"note: {cfg.name} is embeddings-input; serving decodes its "
              f"token codebook after a token prompt")

    # independent streams for init / prompts / sampling: reusing one key
    # correlates the model weights with the benchmark prompts and the
    # sampling noise
    k_init, k_prompts, k_sample = jax.random.split(
        jax.random.PRNGKey(args.seed), 3)
    params = T.init_model(k_init, cfg)
    engine = ServeEngine(cfg=cfg, params=params,
                         max_len=args.prompt_len + args.new_tokens,
                         cache_dtype=jnp.dtype(args.cache_dtype))
    prompts = jax.random.randint(k_prompts, (args.batch, args.prompt_len),
                                 0, cfg.vocab_size)
    t0 = time.time()
    out = engine.generate(prompts, max_new_tokens=args.new_tokens,
                          temperature=args.temperature, key=k_sample)
    dt = time.time() - t0
    toks = args.batch * args.new_tokens
    print(f"generated {out.shape} in {dt:.2f}s "
          f"({toks/dt:.1f} tok/s batch-aggregate)")
    print(out)


if __name__ == "__main__":
    main()
