"""Batched serving driver (smoke-scale on CPU; production mesh on TPU).

  PYTHONPATH=src python -m repro.launch.serve --arch qwen3-1.7b --smoke \
      --batch 4 --prompt-len 16 --new-tokens 16

``--continuous`` switches to the continuous-batching engine: the same
requests run through a churning admit/evict pool over ``--slots``
compiled batch rows (staggered arrivals, per-request sampling params),
reporting tokens/sec, slot occupancy, and per-request latency in ticks.
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import ARCH_IDS, get_config, get_smoke, with_overrides
from repro.models import transformer as T
from repro.serve import ContinuousBatchingEngine, Request, ServeEngine


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS, default="qwen3-1.7b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--linear-impl", default=None)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--new-tokens", type=int, default=16)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--cache-dtype", choices=("bfloat16", "float32"),
                    default="bfloat16",
                    help="KV-cache dtype (default matches the engine's "
                         "bf16 default; float32 for parity debugging)")
    ap.add_argument("--continuous", action="store_true",
                    help="continuous-batching engine: admit/evict the "
                         "requests through a fixed-slot decode tick")
    ap.add_argument("--slots", type=int, default=4,
                    help="compiled batch slots (continuous mode)")
    ap.add_argument("--arrival-every", type=int, default=2,
                    help="ticks between request arrivals (continuous mode)")
    args = ap.parse_args()

    cfg = get_smoke(args.arch) if args.smoke else get_config(args.arch)
    if args.linear_impl:
        cfg = with_overrides(cfg, linear_impl=args.linear_impl)
    if cfg.input_kind != "tokens":
        print(f"note: {cfg.name} is embeddings-input; serving decodes its "
              f"token codebook after a token prompt")

    # independent streams for init / prompts / sampling: reusing one key
    # correlates the model weights with the benchmark prompts and the
    # sampling noise
    k_init, k_prompts, k_sample = jax.random.split(
        jax.random.PRNGKey(args.seed), 3)
    params = T.init_model(k_init, cfg)

    if args.continuous:
        eng = ContinuousBatchingEngine(
            cfg, params, slots=args.slots,
            max_len=args.prompt_len + args.new_tokens,
            cache_dtype=jnp.dtype(args.cache_dtype),
            base_key=k_sample)
        reqs = [Request(prompt=jax.random.randint(
                            jax.random.fold_in(k_prompts, i),
                            (args.prompt_len,), 0, cfg.vocab_size),
                        max_new_tokens=args.new_tokens,
                        temperature=args.temperature, rid=i)
                for i in range(args.batch)]
        arrivals = [i * args.arrival_every for i in range(args.batch)]
        t0 = time.time()
        results, stats = eng.serve(reqs, arrival_ticks=arrivals)
        dt = time.time() - t0
        occ = stats["occupied_slot_ticks"] / max(stats["ticks"]
                                                 * args.slots, 1)
        lat = [results[r.rid]["finished_tick"]
               - results[r.rid]["admitted_tick"] for r in reqs]
        print(f"served {len(reqs)} requests / {stats['tokens']} tokens in "
              f"{stats['ticks']} ticks, {dt:.2f}s "
              f"({stats['tokens']/dt:.1f} tok/s, occupancy {occ:.2f}, "
              f"latency {min(lat)}-{max(lat)} ticks)")
        for r in reqs:
            print(r.rid, results[r.rid]["tokens"])
        return

    engine = ServeEngine(cfg=cfg, params=params,
                         max_len=args.prompt_len + args.new_tokens,
                         cache_dtype=jnp.dtype(args.cache_dtype))
    prompts = jax.random.randint(k_prompts, (args.batch, args.prompt_len),
                                 0, cfg.vocab_size)
    t0 = time.time()
    out = engine.generate(prompts, max_new_tokens=args.new_tokens,
                          temperature=args.temperature, key=k_sample)
    dt = time.time() - t0
    toks = args.batch * args.new_tokens
    print(f"generated {out.shape} in {dt:.2f}s "
          f"({toks/dt:.1f} tok/s batch-aggregate)")
    print(out)


if __name__ == "__main__":
    main()
