"""Roofline-term extraction from compiled dry-run artifacts.

* ``collective_bytes(hlo_text)`` — parse post-optimization HLO and sum the
  result-shape bytes of every all-gather / all-reduce / reduce-scatter /
  all-to-all / collective-permute (cost_analysis does not report these).
* ``roofline_terms(...)`` — the three §Roofline terms in seconds, per
  chip, on TPU v5e constants (197 TFLOP/s bf16, 819 GB/s HBM,
  ~50 GB/s/link ICI).

``compiled.cost_analysis()`` / ``memory_analysis()`` describe the
PER-DEVICE partitioned program, so terms are computed per chip directly:
compute = flops/chip / peak, memory = bytes/chip / bw, collective =
coll_bytes/chip / link_bw.
"""

from __future__ import annotations

import re
import warnings
from typing import Dict, Optional

__all__ = ["collective_bytes", "roofline_terms", "HW", "parse_shape_bytes",
           "sharded_stage_traffic"]

HW = {
    "peak_flops": 197e12,     # bf16 per chip
    "hbm_bw": 819e9,          # bytes/s
    "ici_bw": 50e9,           # bytes/s/link (~ per-chip usable)
}

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1, "s4": 1, "u4": 1,
}

_SHAPE_RE = re.compile(r"\b([a-z0-9]+)\[([\d,]*)\]")
_COLL_OPS = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
             "collective-permute")
# result shapes sit between "= " and " <opname>("
_LINE_RE = re.compile(
    r"=\s+(.*?)\s+(" + "|".join(_COLL_OPS) + r")(?:-start|-done)?\(")


def parse_shape_bytes(shape_str: str) -> int:
    """Sum bytes over every dtype[dims] occurrence in shape_str (handles
    tuple results)."""
    total = 0
    for dtype, dims in _SHAPE_RE.findall(shape_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def collective_bytes(hlo_text: str) -> Dict[str, int]:
    """Per-op-kind result bytes; '-done' twins of async pairs are skipped
    so started collectives are counted once."""
    out = {k: 0 for k in _COLL_OPS}
    for line in hlo_text.splitlines():
        m = _LINE_RE.search(line)
        if not m:
            continue
        if "-done(" in line:
            continue
        kind = m.group(2)
        out[kind] += parse_shape_bytes(m.group(1))
    out["total"] = sum(out[k] for k in _COLL_OPS)
    return out


def sharded_stage_traffic(n_local: int, batch_rows: int, steps,
                          dtype_bytes: int = 4,
                          hw: Optional[dict] = None, *,
                          use_diag: bool = False,
                          use_bias: bool = False,
                          in_width: Optional[int] = None,
                          out_width: Optional[int] = None,
                          fold_boundaries: bool = True,
                          overlap: bool = False,
                          n_row_blocks: Optional[int] = None) -> Dict:
    """Modeled per-chip traffic of a feature-sharded SPM schedule.

    ``steps`` is ``parallel.spm_shard.plan_steps(...)`` output: per
    ``("cross", ell, k)`` stage one collective-permute moves the chip's
    whole ``(batch_rows, n_local)`` slab to its XOR partner; per
    ``("local", off, strides)`` run the fused kernel costs one HBM read +
    one write of the slab (interior run boundaries of a multi-run plan are
    not modeled here — n_local is tile-sized in practice).

    Boundary terms: with ``fold_boundaries=True`` (the executor since the
    kernel-native-boundaries PR) the diag multiplies / bias add ride the
    schedule's boundary steps and a rectangular input is window-read
    straight from the (rows, in_width) operand.  ``ShardPlan.fold_din``
    and the windowed read still require the FIRST step local (a
    cross-starting schedule keeps the explicit d_in elementwise op and
    the gather-fallback window build, charged here), but the OUTPUT side
    folds on every schedule shape: a local ending absorbs d_out/bias into
    its last kernel run, and a cross ending folds them into the 2x2 mix
    epilogue itself (two O(n_local) vector operands applied on the store,
    d_out scaling the mixed result AFTER the add — no batch-wide
    elementwise op, no extra slab round-trip), so the model charges NO
    output-boundary bytes.  The
    always-paid remainder is the single local slice cutting the assembled
    output to ``out_width`` (one slab-portion read + write).
    ``fold_boundaries=False`` reproduces the PRE-fold executor for
    comparison: every enabled diag/bias term is one extra elementwise
    round-trip of the slab regardless of boundary kinds, and rectangular
    widths cost an XLA pad (write the slab from the narrower input) and
    slice (read the slab, write the narrower output) around the square
    core.  The overhead is reported per chip in
    ``boundary_bytes_per_chip`` and included in ``hbm_bytes_per_chip`` /
    ``memory_s``.

    Exposed vs hidden communication: with ``overlap=False`` (the
    step-serial executor) every cross stage's exchange is fully exposed —
    the whole slab must finish its local kernel run before a byte moves,
    and the 2x2 mix waits on the whole-slab permute.  With
    ``overlap=True`` the executor pipelines ``n_row_blocks`` row blocks
    (default: the executor's ``core.eligibility.OVERLAP_ROW_BLOCKS``)
    through the schedule, and a stage's per-block exchange
    hides under (a) OTHER cross stages' exchanges — each XOR distance
    ``k`` pairs over a distinct ICI link class, so stage ``k=2``'s block
    ``i`` flies while stage ``k=1``'s block ``i+1`` flies — and (b) the
    adjacent local compute (HBM-bound kernel time converted to
    ICI-equivalent bytes).  The exposed remainder is the busiest link
    class (less what compute hides, floored at its one-block pipeline
    fill) plus the other links' fill terms:

        exposed = max(bottleneck - compute_hide, bottleneck / nb)
                  + (total - bottleneck) / nb

    clamped to ``[0, total]``; ``hidden = total - exposed``.  The last
    block of each stage has nothing behind it to hide under, which is the
    ``(nb-1)/nb`` factor on the compute-hide term.

    Returns per-stage rows plus totals and roofline seconds on the
    §Roofline HW constants (per-chip HBM vs ICI), so kernel_bench / dryrun
    can place the collective term next to the HBM term.
    """
    hw = hw or HW
    if overlap and n_row_blocks is None:
        # the executor's pipeline depth — shared constant, so the model
        # can never drift from the executed schedule.  (Tiny slabs that
        # degenerate to fewer blocks should pass the plan's actual count.)
        from repro.core.eligibility import OVERLAP_ROW_BLOCKS
        n_row_blocks = OVERLAP_ROW_BLOCKS
    nb = n_row_blocks if overlap else 1
    slab = batch_rows * n_local * dtype_bytes
    stages = []
    link_bytes: Dict[int, int] = {}
    coll_total = hbm_total = 0
    for step in steps:
        if step[0] == "cross":
            stages.append({"kind": "cross", "stage": step[1], "k": step[2],
                           "permute_bytes": slab, "hbm_bytes": 2 * slab})
            link_bytes[step[2]] = link_bytes.get(step[2], 0) + slab
            coll_total += slab
            hbm_total += 2 * slab
        else:
            stages.append({"kind": "local", "stage": step[1],
                           "n_stages": len(step[2]), "permute_bytes": 0,
                           "hbm_bytes": 2 * slab})
            hbm_total += 2 * slab
    if nb <= 1 or not link_bytes:
        exposed = coll_total
    else:
        # hbm_total here is still the bare stage traffic (the boundary
        # terms are added below, after the exposure split)
        bottleneck = max(link_bytes.values())
        compute_hide = (hbm_total / hw["hbm_bw"]) * hw["ici_bw"] \
            * (nb - 1) / nb
        exposed = (max(bottleneck - compute_hide, bottleneck / nb)
                   + (coll_total - bottleneck) / nb)
        exposed = min(max(exposed, 0.0), coll_total)
    exposed = int(round(exposed))
    # pro-rate per stage; the last cross row absorbs the rounding
    # remainder so the stage rows always sum to the per-chip total
    crosses = [row for row in stages if row["kind"] == "cross"]
    shared = 0
    for row in crosses:
        row["exposed_bytes"] = int(round(
            exposed * row["permute_bytes"] / coll_total))
        shared += row["exposed_bytes"]
    if crosses:
        crosses[-1]["exposed_bytes"] += exposed - shared
    boundary = 0
    first_local = bool(steps) and steps[0][0] == "local"
    if fold_boundaries:
        if use_diag and not first_local:
            boundary += 2 * slab               # explicit d_in elementwise
        # d_out/bias fold on EVERY schedule shape: into the last kernel
        # run on a local ending, into the mix epilogue's role vectors on
        # a cross ending (O(n_local) vector cost — not modeled as slab
        # traffic)
        if in_width is not None and not first_local:
            # gather-fallback window build instead of the in-kernel read
            boundary += slab + batch_rows * min(n_local, in_width) \
                * dtype_bytes
        if out_width is not None:
            # the lone always-paid boundary op: the local per-shard slice
            # of the assembled output (read + write of the kept portion)
            boundary += 2 * min(slab, batch_rows * out_width * dtype_bytes)
    else:
        n_elementwise = (2 if use_diag else 0) + (1 if use_bias else 0)
        boundary += n_elementwise * 2 * slab
        if in_width is not None:
            boundary += slab + batch_rows * min(n_local, in_width) \
                * dtype_bytes                       # pad: read d_in, write n
        if out_width is not None:
            boundary += slab + batch_rows * min(n_local, out_width) \
                * dtype_bytes                       # slice: read n, write out
    hbm_total += boundary
    return {"stages": stages,
            "overlap": bool(overlap),
            "n_row_blocks": nb,
            "permute_bytes_per_chip": coll_total,
            "exposed_permute_bytes_per_chip": exposed,
            "hidden_permute_bytes_per_chip": coll_total - exposed,
            "boundary_bytes_per_chip": boundary,
            "hbm_bytes_per_chip": hbm_total,
            "collective_s": coll_total / hw["ici_bw"],
            "exposed_collective_s": exposed / hw["ici_bw"],
            "memory_s": hbm_total / hw["hbm_bw"]}


def roofline_terms(flops_per_chip: float, bytes_per_chip: float,
                   coll_bytes_per_chip: float,
                   hw: Optional[dict] = None) -> Dict[str, float]:
    hw = hw or HW
    t_c = flops_per_chip / hw["peak_flops"]
    t_m = bytes_per_chip / hw["hbm_bw"]
    t_x = coll_bytes_per_chip / hw["ici_bw"]
    terms = {"compute_s": t_c, "memory_s": t_m, "collective_s": t_x}
    dom = max(terms, key=terms.get)
    terms["dominant"] = dom
    bound = max(t_c, t_m, t_x)
    terms["roofline_fraction"] = (t_c / bound) if bound > 0 else 0.0
    return terms


def cost_analysis_terms(compiled) -> Dict[str, float]:
    """Pull flops / bytes-accessed from compiled.cost_analysis(), tolerant
    of backend differences (dict vs list-of-dicts)."""
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0] if ca else {}
    flops = float(ca.get("flops", 0.0))
    byt = float(ca.get("bytes accessed", 0.0))
    return {"flops": flops, "bytes_accessed": byt, "raw_keys": len(ca)}


def memory_analysis_terms(compiled) -> Dict[str, float]:
    """Per-device memory-footprint terms from ``compiled.memory_analysis()``.

    Backends without the analysis raise ``NotImplementedError`` (or an
    ``XlaRuntimeError``, a ``RuntimeError`` subclass) — those degrade to
    ``{}`` WITH a warning so a traffic-model hole is visible instead of
    silently dropping the columns; anything else (a genuine bug) raises.
    """
    try:
        ma = compiled.memory_analysis()
    except (NotImplementedError, RuntimeError) as e:
        warnings.warn(
            f"memory_analysis unavailable on this backend "
            f"({type(e).__name__}: {e}); footprint terms omitted",
            RuntimeWarning, stacklevel=2)
        return {}
    out = {}
    for k in ("argument_size_in_bytes", "output_size_in_bytes",
              "temp_size_in_bytes", "alias_size_in_bytes",
              "generated_code_size_in_bytes"):
        v = getattr(ma, k, None)
        if v is not None:
            out[k] = int(v)
    return out
